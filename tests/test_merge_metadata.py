"""Cross-file metadata merging: distinct-min/max dedup correctness.

The §5 coupon-collector inversion consumes m_min/m_max = the number of
DISTINCT row-group min/max statistics. Merging per-file views must dedup
these across files — summing per-file counts (or deduping only in the
truncated 8-byte key space for BYTE_ARRAY) inflates or deflates diversity
and skews the estimate. Covers numeric collisions and BYTE_ARRAY key+repr
collisions, plus associativity (the property `StatsCatalog.update()` relies
on for incremental merging).
"""
import numpy as np
import pytest

from repro.catalog import merge_column_metadata
from repro.columnar import format as fmt
from repro.columnar import read_footer, write_file
from repro.columnar.reader import column_metadata_from_footer
from repro.columnar.writer import WriterOptions
from repro.core.ndv.types import ColumnMetadata, PhysicalType


def _numeric_meta(mins, maxs, name="c"):
    r = len(mins)
    return ColumnMetadata(
        chunk_sizes=np.full(r, 1000.0),
        chunk_rows=np.full(r, 100.0),
        chunk_nulls=np.zeros(r),
        chunk_dict_encoded=np.ones(r, bool),
        mins=np.asarray(mins, np.float64),
        maxs=np.asarray(maxs, np.float64),
        min_lengths=np.full(r, 8.0),
        max_lengths=np.full(r, 8.0),
        distinct_min_count=float(np.unique(mins).size),
        distinct_max_count=float(np.unique(maxs).size),
        physical_type=PhysicalType.INT64,
        column_name=name,
    )


def _bytes_meta(min_strs, max_strs, name="s"):
    ptype = PhysicalType.BYTE_ARRAY
    keys = lambda vals: np.array(  # noqa: E731
        [fmt.stat_key(v, ptype) for v in vals], np.float64
    )
    lens = lambda vals: np.array(  # noqa: E731
        [len(v.encode()) for v in vals], np.float64
    )
    r = len(min_strs)
    mins, maxs = keys(min_strs), keys(max_strs)
    return ColumnMetadata(
        chunk_sizes=np.full(r, 1000.0),
        chunk_rows=np.full(r, 100.0),
        chunk_nulls=np.zeros(r),
        chunk_dict_encoded=np.ones(r, bool),
        mins=mins,
        maxs=maxs,
        min_lengths=lens(min_strs),
        max_lengths=lens(max_strs),
        distinct_min_count=float(
            len({(k, l, s) for k, l, s in zip(mins, lens(min_strs), min_strs)})
        ),
        distinct_max_count=float(
            len({(k, l, s) for k, l, s in zip(maxs, lens(max_strs), max_strs)})
        ),
        physical_type=ptype,
        column_name=name,
        min_reprs=np.array(min_strs, object),
        max_reprs=np.array(max_strs, object),
    )


def test_numeric_collision_dedup():
    # mins 10 appears in both files; maxs 90 appears in both.
    a = _numeric_meta(mins=[10.0, 20.0], maxs=[50.0, 90.0])
    b = _numeric_meta(mins=[10.0, 30.0], maxs=[90.0, 95.0])
    m = merge_column_metadata([a, b])
    assert m.distinct_min_count == 3.0  # {10, 20, 30}
    assert m.distinct_max_count == 3.0  # {50, 90, 95}
    # matches the old inline pipeline dedup for numerics
    assert m.distinct_min_count == len({float(x) for p in (a, b) for x in p.mins})
    # chunk-level fields concatenate in order
    np.testing.assert_array_equal(m.mins, [10.0, 20.0, 10.0, 30.0])
    assert m.num_row_groups == 4
    assert m.num_values == a.num_values + b.num_values


def test_byte_array_shared_prefix_distinct_lengths():
    # Same 8-byte key prefix, different lengths: distinct values.
    a = _bytes_meta(["aaaaaaaaX"], ["zzz"])
    b = _bytes_meta(["aaaaaaaaXYZ"], ["zzz"])
    m = merge_column_metadata([a, b])
    assert float(m.mins[0]) == float(m.mins[1])  # keys collide
    assert m.distinct_min_count == 2.0           # lengths resolve them
    assert m.distinct_max_count == 1.0           # identical max dedups


def test_byte_array_shared_prefix_same_length_distinct_repr():
    # Same key, same length — only the repr tells them apart.
    a = _bytes_meta(["aaaaaaaabb"], ["q"])
    b = _bytes_meta(["aaaaaaaacc"], ["q"])
    m = merge_column_metadata([a, b])
    assert float(m.mins[0]) == float(m.mins[1])
    assert float(m.min_lengths[0]) == float(m.min_lengths[1])
    assert m.distinct_min_count == 2.0


def test_byte_array_identical_values_across_files_count_once():
    a = _bytes_meta(["hello", "world"], ["x", "y"])
    b = _bytes_meta(["hello", "apple"], ["y", "z"])
    m = merge_column_metadata([a, b])
    assert m.distinct_min_count == 3.0  # {hello, world, apple}
    assert m.distinct_max_count == 3.0  # {x, y, z}


def test_merge_associative_and_fixed_point():
    parts = [
        _numeric_meta(mins=[1.0, 2.0], maxs=[5.0, 6.0]),
        _numeric_meta(mins=[2.0, 3.0], maxs=[6.0, 7.0]),
        _numeric_meta(mins=[1.0, 4.0], maxs=[7.0, 8.0]),
    ]
    flat = merge_column_metadata(parts)
    nested = merge_column_metadata(
        [merge_column_metadata(parts[:2]), parts[2]]
    )
    assert flat.distinct_min_count == nested.distinct_min_count
    assert flat.distinct_max_count == nested.distinct_max_count
    np.testing.assert_array_equal(flat.mins, nested.mins)
    np.testing.assert_array_equal(flat.chunk_sizes, nested.chunk_sizes)
    one = merge_column_metadata([parts[0]])
    assert one is parts[0]


def test_merge_rejects_mismatched_types():
    a = _numeric_meta(mins=[1.0], maxs=[2.0])
    b = _bytes_meta(["x"], ["y"], name="c")
    with pytest.raises(ValueError):
        merge_column_metadata([a, b])
    with pytest.raises(ValueError):
        merge_column_metadata([])


def test_end_to_end_from_written_files(tmp_path):
    # Two shards with overlapping row-group extrema, through the real
    # writer/reader, including a string column with shared 8-byte prefixes.
    rg = 64
    strings0 = np.array(
        ["prefix__alpha"] * rg + ["prefix__beta"] * rg
    )
    strings1 = np.array(
        ["prefix__alpha"] * rg + ["prefix__gamma"] * rg
    )
    ints0 = np.concatenate([np.full(rg, 10), np.full(rg, 20)]).astype(np.int64)
    ints1 = np.concatenate([np.full(rg, 10), np.full(rg, 30)]).astype(np.int64)
    write_file(
        str(tmp_path / "f0"), {"s": strings0, "i": ints0},
        options=WriterOptions(row_group_size=rg),
    )
    write_file(
        str(tmp_path / "f1"), {"s": strings1, "i": ints1},
        options=WriterOptions(row_group_size=rg),
    )
    metas = {
        name: [
            column_metadata_from_footer(read_footer(str(tmp_path / f)), name)
            for f in ("f0", "f1")
        ]
        for name in ("s", "i")
    }
    mi = merge_column_metadata(metas["i"])
    # per-rg mins: f0 {10,20}, f1 {10,30} -> distinct {10,20,30}
    assert mi.distinct_min_count == 3.0
    assert mi.distinct_min_count == float(np.unique(np.concatenate(
        [m.mins for m in metas["i"]]
    )).size)
    ms = merge_column_metadata(metas["s"])
    # string mins per rg: {alpha, beta} + {alpha, gamma}; all share the
    # 8-byte "prefix__" key, so key-only dedup would (wrongly) give 1.
    assert float(np.unique(ms.mins).size) == 1
    assert ms.distinct_min_count == 3.0
