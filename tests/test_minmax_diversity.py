"""Unit + property tests for coupon-collector inversion (paper §5)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ndv import minmax_diversity as mm


def test_forward_model_matches_simulation():
    """Eq 6 against Monte-Carlo draws."""
    rng = np.random.default_rng(0)
    N, k = 500, 300
    sims = [
        np.unique(rng.integers(0, N, k)).size for _ in range(300)
    ]
    expected = float(mm.coupon_expected(jnp.float32(N), jnp.float32(k)))
    assert abs(np.mean(sims) - expected) / expected < 0.02


def test_exact_inversion_unsaturated():
    n = jnp.full((64,), 256.0)
    true_ndv = jnp.asarray(np.geomspace(4, 5000, 64), jnp.float32)
    m = mm.coupon_expected(true_ndv, n)
    res = mm.invert_coupon(m, n)
    err = np.abs(np.asarray(res.ndv) - np.asarray(true_ndv)) / np.asarray(true_ndv)
    # near-saturation (m ~ n) is ill-conditioned; check the well-posed region
    ok = np.asarray(m) < 0.95 * np.asarray(n)
    assert np.max(err[ok]) < 0.02, err[ok].max()


def test_saturated_flagged():
    res = mm.invert_coupon(jnp.array([50.0]), jnp.array([50.0]))
    assert bool(res.saturated[0])
    assert float(res.ndv[0]) >= 50.0


@given(
    ndv=st.integers(2, 10**6),
    n=st.integers(4, 4096),
)
@settings(max_examples=60, deadline=None)
def test_inversion_property(ndv, n):
    m = float(mm.coupon_expected(jnp.float32(ndv), jnp.float32(n)))
    res = mm.invert_coupon(jnp.array([m], jnp.float32), jnp.array([float(n)], jnp.float32))
    got = float(res.ndv[0])
    assert got >= 1.0
    if m < 0.9 * n:  # well-conditioned regime
        assert abs(got - ndv) / ndv < 0.1


def test_minmax_takes_larger_side():
    res = mm.estimate_minmax_diversity(
        jnp.array([10.0]), jnp.array([40.0]), jnp.array([64.0])
    )
    assert float(res.ndv[0]) == float(res.ndv_from_max[0])
    assert float(res.ndv_from_max[0]) > float(res.ndv_from_min[0])


def test_monotonic_in_m():
    n = jnp.full(5, 128.0)
    m = jnp.asarray([10.0, 30.0, 60.0, 90.0, 110.0])
    res = mm.invert_coupon(m, n)
    assert np.all(np.diff(np.asarray(res.ndv)) > 0)
