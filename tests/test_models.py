"""Per-architecture smoke tests (reduced configs, one forward + train step)
and decode-vs-forward consistency for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import params as P
from repro.models import registry
from repro.models.config import MoEConfig
from repro.train import optimizer as opt
from repro.train.train_step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((b, 16, cfg.encdec.frontend_dim), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones(
            (b, cfg.vlm.num_patches, cfg.vlm.vision_dim), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward(arch):
    cfg = registry.get_smoke_config(arch).scaled(dtype="float32", param_dtype="float32")
    model = registry.build_model(cfg)
    prm = P.init_params(model.specs(), KEY, jnp.float32)
    batch = _batch(cfg)
    out = model.forward(prm, batch)
    assert out.logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_smoke_config(arch).scaled(dtype="float32", param_dtype="float32")
    model = registry.build_model(cfg)
    step = jax.jit(make_train_step(
        model, cfg, opt.AdamWConfig(lr=1e-3), schedule=lambda s: jnp.float32(1.0)
    ))
    state = init_train_state(model, cfg)
    batch = _batch(cfg)
    batch["labels"] = batch["tokens"]
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics.loss))
    assert float(metrics.grad_norm) > 0


@pytest.mark.parametrize(
    "arch", ["qwen2_7b", "qwen3_0_6b", "yi_6b", "deepseek_coder_33b",
             "rwkv6_7b", "zamba2_1_2b", "llava_next_mistral_7b"]
)
def test_decode_matches_forward(arch):
    cfg = registry.get_smoke_config(arch).scaled(dtype="float32", param_dtype="float32")
    model = registry.build_model(cfg)
    prm = P.init_params(model.specs(), KEY, jnp.float32)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    full = model.forward(prm, {"tokens": toks}).logits
    sp = model.cache_spec(b, s)
    cache = {
        k: jnp.zeros(v.shape, jnp.int32 if "index" in k else jnp.float32)
        for k, v in sp.items()
    }
    outs = []
    for t in range(s):
        o = model.decode_step(
            prm, toks[:, t:t + 1], jnp.full((b, 1), t, jnp.int32), cache
        )
        cache = o.cache
        outs.append(o.logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4)


def test_moe_decode_matches_forward_high_capacity():
    cfg = registry.get_smoke_config("mixtral_8x22b").scaled(
        dtype="float32", param_dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=16.0),
    )
    model = registry.build_model(cfg)
    prm = P.init_params(model.specs(), KEY, jnp.float32)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    full = model.forward(prm, {"tokens": toks}).logits
    cache = {
        k: jnp.zeros(v.shape, jnp.int32 if "index" in k else jnp.float32)
        for k, v in model.cache_spec(b, s).items()
    }
    outs = []
    for t in range(s):
        o = model.decode_step(prm, toks[:, t:t+1], jnp.full((b, 1), t, jnp.int32), cache)
        cache = o.cache
        outs.append(o.logits[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), atol=2e-4
    )


def test_swa_ring_buffer_wrap():
    cfg = registry.get_smoke_config("mixtral_8x22b").scaled(
        dtype="float32", param_dtype="float32", sliding_window=8,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=16.0),
    )
    model = registry.build_model(cfg)
    prm = P.init_params(model.specs(), KEY, jnp.float32)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    full = model.forward(prm, {"tokens": toks}).logits
    cache = {
        k: jnp.zeros(v.shape, jnp.int32 if "index" in k else jnp.float32)
        for k, v in model.cache_spec(b, s).items()
    }
    assert cache["k"].shape[2] == 8  # ring buffer sized to the window
    outs = []
    for t in range(s):
        o = model.decode_step(prm, toks[:, t:t+1], jnp.full((b, 1), t, jnp.int32), cache)
        cache = o.cache
        outs.append(o.logits[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), atol=2e-4
    )


def test_blockwise_attention_equals_dense():
    import repro.models.layers as L

    cfg = registry.get_smoke_config("qwen2_7b").scaled(dtype="float32", param_dtype="float32")
    model = registry.build_model(cfg)
    prm = P.init_params(model.specs(), KEY, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 64), 0, cfg.vocab_size)
    dense = model.forward(prm, {"tokens": toks}).logits
    old = (L.BLOCKWISE_MIN_SEQ, L.Q_CHUNK, L.KV_CHUNK)
    try:
        L.BLOCKWISE_MIN_SEQ, L.Q_CHUNK, L.KV_CHUNK = 32, 16, 16
        blk = model.forward(prm, {"tokens": toks}).logits
    finally:
        L.BLOCKWISE_MIN_SEQ, L.Q_CHUNK, L.KV_CHUNK = old
    np.testing.assert_allclose(np.asarray(blk), np.asarray(dense), atol=2e-4)


def test_param_count_analytic_close_to_specs():
    """ModelConfig.param_count() (used for 6ND roofline) tracks real specs."""
    from repro.models.params import param_count

    for arch in registry.ARCHS:
        cfg = registry.get_config(arch)
        model = registry.build_model(cfg)
        spec_n = param_count(model.specs())
        analytic = cfg.param_count()
        ratio = spec_n / analytic
        assert 0.8 < ratio < 1.25, (arch, spec_n, analytic, ratio)
