"""Unified telemetry tier: metrics registry, tracing, scrape endpoints.

Covers the observability acceptance criteria:
  * concurrent counter/histogram writes are EXACT (striped locks, and the
    hot-path label memo aliases every kwarg ordering to one cell)
  * Prometheus text exposition survives hostile label values and obeys
    the v0.0.4 line grammar (cumulative buckets, +Inf terminal, escaping)
  * weakref stats views read live objects and vanish when collected
  * traceparent propagation: header grammar round-trip, wire-frame trace
    section, contextvar parenting, interest-based ring retention
  * HTTP e2e: one trace id across router -> replica -> service -> engine
    for a fleet `/batch`; a killed replica's failover shows up as a
    re-parented sibling attempt, never an orphan
  * `/metrics` + `/debug/traces` on both tiers; pool counters in router
    `/health`; `slow_request_ms` structured logging
"""
import gc
import json
import logging
import os
import re
import threading
import time
from dataclasses import dataclass

import numpy as np
import pytest

from repro import obs
from repro.columnar.writer import WriterOptions, write_file
from repro.fleet import DatasetRegistry, Fleet, StatsRequest, StatsRouter
from repro.obs.metrics import (
    MetricsRegistry,
    add_label_to_exposition,
    escape_label_value,
)
from repro.service import StatsServer, StatsService, fetch_json
from repro.wire import decode_frame, decode_traceparent, encode_frame, fetch


def _write(root, name, seed, vocab=64):
    rng = np.random.default_rng(seed)
    return write_file(
        os.path.join(root, name),
        {
            "tok": rng.integers(0, vocab, 512).astype(np.int64),
            "val": np.round(rng.uniform(0, 100, 512), 1),
        },
        options=WriterOptions(row_group_size=128),
    )


@pytest.fixture(autouse=True)
def _telemetry_on():
    obs.set_enabled(True)
    obs.collector().clear()
    yield
    obs.set_enabled(True)


@pytest.fixture()
def dataset(tmp_path):
    root = str(tmp_path / "ds")
    for i in range(3):
        _write(root, f"shard_{i:03d}", seed=i)
    return root


@pytest.fixture()
def fleet_registry(tmp_path):
    reg = DatasetRegistry()
    for name, seed in (("alpha", 10), ("beta", 20)):
        root = str(tmp_path / name)
        for i in range(2):
            _write(root, f"shard_{i:03d}", seed=seed + i, vocab=32)
        reg.add("wh", name, root)
    return reg


# -- metrics registry --------------------------------------------------------


def test_concurrent_increments_exact_across_label_orderings():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "t")
    h = reg.histogram("h", "h", buckets=(1.0, 10.0))
    n_threads, n_iter = 8, 500

    def worker(tid):
        for i in range(n_iter):
            # alternate kwarg order and value type: every variant must
            # alias the same canonical cell
            if i % 2:
                c.inc(a="1", b="2")
                h.observe(0.5, k="x")
            else:
                c.inc(b=2, a=1)
                h.observe(20.0, k="x")

    threads = [
        threading.Thread(target=worker, args=(t,)) for t in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value(a="1", b="2") == n_threads * n_iter
    # one series in the exposition, not one per kwarg ordering
    text = reg.exposition()
    assert text.count("t_total{") == 1
    assert f't_total{{a="1",b="2"}} {n_threads * n_iter}' in text
    # histogram: exact count/cumulative buckets; half the samples > 10
    assert f'h_count{{k="x"}} {n_threads * n_iter}' in text
    assert f'h_bucket{{k="x",le="1"}} {n_threads * n_iter // 2}' in text
    assert f'h_bucket{{k="x",le="+Inf"}} {n_threads * n_iter}' in text


def test_bound_handles_write_same_cells():
    reg = MetricsRegistry()
    c = reg.counter("b_total")
    h = reg.histogram("bh", buckets=(1.0,))
    c.labels(route="x").inc()
    c.inc(route="x")
    h.labels(route="x").observe(0.5)
    h.observe(2.0, route="x")
    assert c.value(route="x") == 2
    text = reg.exposition()
    assert 'bh_count{route="x"} 2' in text
    assert 'bh_bucket{route="x",le="1"} 1' in text


def test_exposition_escapes_hostile_labels_and_obeys_grammar():
    reg = MetricsRegistry()
    hostile = 'quo"te\\slash\nnewline'
    reg.counter("evil_total", 'help with \\ and\nnewline').inc(ds=hostile)
    reg.gauge("g").set(-1.5, k="v")
    reg.histogram("lat", buckets=(0.1, 1.0)).observe(0.05)
    text = reg.exposition()
    assert escape_label_value(hostile) == 'quo\\"te\\\\slash\\nnewline'
    assert f'evil_total{{ds="{escape_label_value(hostile)}"}} 1\n' in text
    # v0.0.4 line grammar: every sample line is name[{labels}] value,
    # with no raw newline/quote inside a label value
    sample = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
        r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
        r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})?'
        r' (NaN|[+-]?Inf|-?[0-9.e+-]+)$'
    )
    comment = re.compile(r"^# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* .*$")
    for line in text.splitlines():
        pat = comment if line.startswith("#") else sample
        assert pat.match(line), f"bad exposition line: {line!r}"
    # histogram buckets are cumulative and terminate at +Inf == count
    assert text.index('lat_bucket{le="0.1"} 1') < text.index(
        'lat_bucket{le="+Inf"} 1'
    )
    assert "lat_count 1" in text


def test_stats_view_reads_live_object_and_dies_with_it():
    @dataclass
    class MyStats:
        hits: int = 0
        ratio: float = 0.0
        _private: int = 7

    reg = MetricsRegistry()
    s = MyStats()
    reg.register_stats_view("my", {"who": "a"}, s)
    s.hits = 3
    s.ratio = 0.5
    text = reg.exposition()
    assert 'my_hits{who="a"} 3' in text
    assert 'my_ratio{who="a"} 0.5' in text
    assert "_private" not in text
    del s
    gc.collect()
    assert "my_hits" not in reg.exposition()


def test_add_label_to_exposition_injects_everywhere():
    blob = (
        "# TYPE x_total counter\n"
        "x_total 3\n"
        'y_bucket{le="+Inf"} 2\n'
    )
    out = add_label_to_exposition(blob, {"replica": "r0"})
    assert out == (
        'x_total{replica="r0"} 3\n'
        'y_bucket{le="+Inf",replica="r0"} 2\n'
    )


def test_disabled_telemetry_is_a_noop():
    reg = MetricsRegistry()
    c = reg.counter("off_total")
    bound = c.labels(k="v")
    obs.set_enabled(False)
    c.inc(k="v")
    bound.inc()
    reg.histogram("offh").observe(1.0)
    with obs.root_span("nope") as sp:
        assert sp.trace_id is None
        assert obs.span("child").trace_id is None
    obs.set_enabled(True)
    assert c.value(k="v") == 0
    assert obs.collector().traces() == []


# -- tracing primitives ------------------------------------------------------


def test_traceparent_grammar_roundtrip():
    tp = obs.format_traceparent("ab" * 16, "cd" * 8)
    assert obs.parse_traceparent(tp) == ("ab" * 16, "cd" * 8)
    for bad in (
        None, "", "junk", "00-short-cd-01",
        "00-" + "g" * 32 + "-" + "cd" * 8 + "-01",   # non-hex
        "00-" + "0" * 32 + "-" + "cd" * 8 + "-01",   # all-zero trace id
        "00-" + "ab" * 16 + "-" + "0" * 16 + "-01",  # all-zero span id
        "00-" + "ab" * 16 + "-" + "cd" * 8,          # missing flags
    ):
        assert obs.parse_traceparent(bad) is None, bad


def test_wire_frame_carries_traceparent_section():
    payload = {"tuples": [{"mode": "paper"}]}
    tp = obs.format_traceparent("12" * 16, "34" * 8)
    raw = encode_frame(payload, traceparent=tp)
    assert decode_traceparent(raw) == tp
    assert decode_frame(raw) == payload  # section is out-of-band
    assert decode_traceparent(encode_frame(payload)) is None
    assert decode_traceparent(b"not a frame") is None


def test_span_nesting_and_ids():
    with obs.root_span("root", method="GET") as root:
        assert re.fullmatch(r"[0-9a-f]{32}", root.trace_id)
        assert re.fullmatch(r"[0-9a-f]{16}", root.span_id)
        assert root.parent_id is None
        assert obs.current_span() is root
        assert obs.current_traceparent() == root.traceparent
        with obs.span("child") as child:
            assert child.trace_id == root.trace_id
            assert child.parent_id == root.span_id
            assert obs.current_span() is child
        assert obs.current_span() is root
    assert obs.current_span() is None
    # joined trace: the remote parent's ids are adopted
    with obs.root_span("joined", traceparent=root.traceparent) as j:
        assert j.trace_id == root.trace_id
        assert j.parent_id == root.span_id
    # no active trace -> child spans are free no-ops
    assert obs.span("orphan").trace_id is None


def test_ring_retention_is_interest_based():
    col = obs.collector()
    with obs.root_span("boring"):
        pass  # childless local root: latency is in the histograms already
    assert col.traces() == []
    with obs.root_span("kept") as sp:
        sp.keep_trace()
    with obs.root_span("parent"):
        with obs.span("child"):
            pass
    with obs.root_span("joined", traceparent=sp.traceparent):
        pass  # remote parent -> always retained
    with pytest.raises(RuntimeError):
        with obs.root_span("failed"):
            raise RuntimeError("boom")
    spans = [s for t in col.traces() for s in t]
    names = {s.name for s in spans}
    assert names == {"kept", "parent", "child", "joined", "failed"}
    assert "boring" not in names
    failed = next(s for s in spans if s.name == "failed")
    assert "RuntimeError" in failed.attributes["error"]
    # "joined" adopted the remote parent's trace id, so it groups with it
    joined = next(s for s in spans if s.name == "joined")
    assert joined.trace_id == sp.trace_id


def test_collector_bound_and_recency():
    from repro.obs.trace import Span, TraceCollector, _TRIM_SLACK

    col = TraceCollector(max_spans=16)
    for i in range(200):
        col.span_ended(Span(f"{i:032x}", f"{i:016x}", None, f"s{i}"))
    assert len(col._done) <= 16 + _TRIM_SLACK
    got = col.traces(limit=4)
    assert [t[0].name for t in got] == ["s199", "s198", "s197", "s196"]
    assert col.find(f"{199:032x}")[0].name == "s199"
    col.clear()
    assert col.traces() == []


def test_trace_tree_shapes():
    from repro.obs.trace import Span, trace_tree

    root = Span("t" * 32, "a" * 16, None, "root")
    kid = Span("t" * 32, "b" * 16, "a" * 16, "kid")
    orphan = Span("t" * 32, "c" * 16, "ffff" * 4, "orphan")
    tree = trace_tree([kid, root])
    assert tree["name"] == "root"
    assert [c["name"] for c in tree["children"]] == ["kid"]
    multi = trace_tree([root, orphan])
    assert multi["name"] == "(multiple roots)"
    assert {c["name"] for c in multi["children"]} == {"root", "orphan"}


# -- HTTP e2e ----------------------------------------------------------------


def _poll_trace(traces_url, root_name, timeout=5.0):
    """Scrape /debug/traces until a trace rooted at `root_name` appears.

    Spans land in the collector on the server thread AFTER the response
    body is flushed (the span wraps the send), so a client that scrapes
    immediately can see a trace whose root hasn't ended yet. Children
    always end before their root, so once the root is visible the whole
    tree is.
    """
    deadline = time.monotonic() + timeout
    while True:
        status, _, traces = fetch_json(traces_url)
        assert status == 200
        match = [t for t in traces["traces"] if t["name"] == root_name]
        if match or time.monotonic() >= deadline:
            assert match, [t["name"] for t in traces["traces"]]
            return match[0]
        time.sleep(0.01)


def test_service_trace_spans_engine_and_scrape_endpoints(dataset):
    with StatsServer(StatsService(dataset)) as srv:
        obs.collector().clear()
        status, _, _ = fetch_json(srv.url + "/estimate?mode=improved")
        assert status == 200

        tree = _poll_trace(
            srv.url + "/debug/traces?limit=5", "service.estimate"
        )
        assert tree["attributes"]["status"] == 200

        def names(node):
            yield node["name"]
            for c in node["children"]:
                yield from names(c)

        seen = set(names(tree))
        assert "service.compute" in seen
        assert "engine.pack" in seen  # cold request reached the engine
        ids = set()

        def tids(node):
            ids.add(node["trace_id"])
            for c in node["children"]:
                tids(c)

        tids(tree)
        assert len(ids) == 1  # one trace id across HTTP -> engine

        status, _, body = fetch_json(srv.url + "/debug/traces?limit=junk")
        assert status == 400

        import urllib.request

        with urllib.request.urlopen(srv.url + "/metrics") as r:
            assert r.status == 200
            assert "version=0.0.4" in r.headers["Content-Type"]
            text = r.read().decode()
        assert re.search(
            r'ndv_http_requests_total\{[^}]*route="estimate"[^}]*\} \d+', text
        )
        assert re.search(
            r'ndv_http_request_seconds_bucket\{[^}]*tier="service"', text
        )
        assert re.search(r"ndv_service_requests\b", text)  # stats view


def test_slow_request_logging(dataset, caplog):
    with StatsServer(
        StatsService(dataset), slow_request_ms=0.0
    ) as srv:
        with caplog.at_level(logging.WARNING, logger="repro.obs.slow"):
            fetch_json(srv.url + "/estimate?mode=paper")
            # the line is emitted on the server thread after the response
            # is written — give it a moment to land
            deadline = time.monotonic() + 5.0
            lines = []
            while not lines and time.monotonic() < deadline:
                lines = [r.getMessage() for r in caplog.records
                         if "slow_request" in r.getMessage()]
                time.sleep(0.01)
        assert lines, "expected a structured slow-request line"
        assert "tier=service" in lines[0]
        assert "endpoint=/estimate" in lines[0]
        assert "trace_id=" in lines[0]
    # default is OFF: no records without the threshold
    caplog.clear()
    with StatsServer(StatsService(dataset)) as srv:
        with caplog.at_level(logging.WARNING, logger="repro.obs.slow"):
            fetch_json(srv.url + "/estimate?mode=paper")
            time.sleep(0.05)
        assert not [r for r in caplog.records
                    if "slow_request" in r.getMessage()]


def test_fleet_batch_single_trace_and_router_scrapes(fleet_registry):
    router = StatsRouter(Fleet(fleet_registry, replicas_per_dataset=2)).start()
    try:
        obs.collector().clear()
        tuples = [
            {"namespace": "wh", "dataset": "alpha", "mode": "improved"},
            {"namespace": "wh", "dataset": "beta", "mode": "paper"},
        ]
        from repro.wire import ConnectionPool

        pool = ConnectionPool(name="obs_test")
        status, _, env = fetch(router.url + "/batch", pool=pool,
                               method="POST", payload={"tuples": tuples})
        assert status == 200
        assert [r["status"] for r in env["responses"]] == [200, 200]

        batch = _poll_trace(
            router.url + "/debug/traces?limit=10", "router.batch"
        )

        def walk(node):
            yield node
            for c in node["children"]:
                yield from walk(c)

        nodes = list(walk(batch))
        names = {n["name"] for n in nodes}
        # router -> per-replica sub-batches -> service superpack -> engine,
        # all under ONE trace id
        assert "replica.sub_batch" in names
        assert "service.superpack" in names
        assert len({n["trace_id"] for n in nodes}) == 1
        subs = [n for n in nodes if n["name"] == "replica.sub_batch"]
        assert all(n["parent_id"] == batch["span_id"] for n in subs)

        # router /metrics aggregates its own registry (local replicas
        # write the same process registry, so no replica label here)
        status, _, _ = fetch_json(router.url + "/datasets")
        import urllib.request

        with urllib.request.urlopen(router.url + "/metrics") as r:
            text = r.read().decode()
        assert re.search(
            r'ndv_http_requests_total\{[^}]*tier="router"', text
        )
        assert "ndv_fleet_batches" in text

        # pool counters ride the router health payload (remote hops only
        # carry pools; local replicas legitimately have none)
        status, _, health = fetch_json(router.url + "/health")
        assert status == 200 and "wh/alpha" in health["datasets"]
        pool.close()
    finally:
        router.stop()


def test_fleet_failover_reparents_attempt_spans(fleet_registry):
    router = StatsRouter(Fleet(fleet_registry, replicas_per_dataset=2)).start()
    try:
        url = router.url_for("wh", "alpha", "estimate") + "?mode=improved"
        status, _, _ = fetch_json(url)
        assert status == 200
        rset = router.fleet.sets["wh/alpha"]
        victim = rset.rank(StatsRequest("estimate", "improved").identity)[0]
        victim.kill()
        obs.collector().clear()
        status, _, _ = fetch_json(url)
        assert status == 200  # failover answered
        tree = _poll_trace(
            router.url + "/debug/traces?limit=5", "router.estimate"
        )
        calls = [c for c in tree["children"] if c["name"] == "replica.call"]
        assert len(calls) == 2, "failed attempt + retry, both re-parented"
        assert [c["attributes"]["attempt"] for c in calls] == [1, 2]
        assert "error" in calls[0]["attributes"]
        assert calls[0]["attributes"]["replica"] == victim.name
        assert "error" not in calls[1]["attributes"]
        # both attempts are SIBLINGS under the router span (re-parented,
        # not orphaned under the dead attempt)
        assert all(c["parent_id"] == tree["span_id"] for c in calls)
    finally:
        router.stop()


def test_remote_replica_scrape_rides_router_metrics(dataset):
    from repro.fleet import RemoteReplica

    with StatsServer(StatsService(dataset)) as upstream:
        remote = RemoteReplica("up", upstream.url)
        try:
            fetch_json(upstream.url + "/estimate?mode=paper")
            text = remote.scrape_metrics()
            assert text and "ndv_http_requests_total" in text
            labeled = add_label_to_exposition(text, {"replica": remote.name})
            assert re.search(
                r'ndv_http_requests_total\{[^}]*replica="up"', labeled
            )
        finally:
            remote.stop()


def test_etag_neutral_to_telemetry_state(dataset):
    with StatsServer(StatsService(dataset)) as srv:
        _, etag_on, body_on = fetch_json(srv.url + "/estimate?mode=improved")
    obs.set_enabled(False)
    try:
        with StatsServer(StatsService(dataset)) as srv:
            _, etag_off, body_off = fetch_json(
                srv.url + "/estimate?mode=improved"
            )
    finally:
        obs.set_enabled(True)
    assert etag_off == etag_on
    assert json.dumps(body_off, sort_keys=True) == json.dumps(
        body_on, sort_keys=True
    )
