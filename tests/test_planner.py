"""Planner math: graph validation, enumeration, batched-vs-reference parity.

The load-bearing claims pinned here:
  * graph validation rejects exactly what must 400 at the HTTP layer —
    disconnected graphs, self-joins, unknown tables, junk fields — and
    `identity()` is insensitive to table/edge listing order
  * enumeration is deterministic: exhaustive (lexicographic) when the
    plan space fits `max_plans`, seed-pinned sampling with the identity
    permutation first when it does not
  * the batched JAX scorer matches the pure-Python float32 reference fold
    BIT-FOR-BIT over randomized connected graphs — the parity contract
    that makes `/cost` bodies byte-identical across replicas
  * the cost model degrades conservatively: NDV <= 0 clamps to 1 (edge
    becomes a pass-through), a join step with no connecting edge is a
    cross product, ties break on the lexicographically smallest plan
"""
import math

import numpy as np
import pytest

from repro.planner import (
    ColumnStats,
    DEFAULT_MAX_PLANS,
    TableStats,
    compute_cost,
    enumerate_plans,
    make_graph,
    parse_join_graph,
    parse_max_plans,
    plan_space_size,
    reference_cost,
    score_plans,
)
from repro.planner.api import sequential_reference


def _graph(n_tables, edges, **table_kwargs):
    payload = {
        "tables": [{"name": f"t{i}", **table_kwargs} for i in range(n_tables)],
        "edges": [
            {"left": f"t{a}", "left_column": "k", "right": f"t{b}",
             "right_column": "k"}
            for a, b in edges
        ],
    }
    return parse_join_graph(payload)


def _stats(graph, rows_by_table, ndv_by_table):
    return {
        t.name: TableStats(
            rows=float(rows_by_table[t.name]),
            columns={
                col: ColumnStats(ndv=float(ndv_by_table[t.name]), non_null=1)
                for col in graph.columns_by_table()[t.name]
            } or {"k": ColumnStats(ndv=float(ndv_by_table[t.name]),
                                   non_null=1)},
        )
        for t in graph.tables
    }


# -- graph validation ---------------------------------------------------------


def test_single_table_graph_costs_zero():
    g = parse_join_graph({"tables": [{"name": "solo"}], "edges": []})
    body = compute_cost(
        g, {"solo": TableStats(rows=1000.0, columns={})},
        mode="paper", max_plans=DEFAULT_MAX_PLANS,
    )
    assert body["best_order"] == ["solo"]
    assert body["joins"] == []
    assert body["total_cost"] == 0.0
    assert body["plans_scored"] == 1 and body["plan_space"] == 1
    assert body["enumeration"] == "exhaustive"


def test_disconnected_graph_rejected():
    with pytest.raises(ValueError, match="disconnected"):
        _graph(3, [(0, 1)])  # t2 shares no edge with {t0, t1}
    with pytest.raises(ValueError, match="disconnected"):
        _graph(2, [])


def test_graph_junk_rejected():
    base = {"tables": [{"name": "a"}], "edges": []}
    with pytest.raises(ValueError, match="unknown"):
        parse_join_graph({**base, "surprise": 1})
    with pytest.raises(ValueError, match="unknown"):
        parse_join_graph(
            {"tables": [{"name": "a", "rows": 5}], "edges": []}
        )
    with pytest.raises(ValueError):
        parse_join_graph({"tables": [], "edges": []})
    with pytest.raises(ValueError):  # duplicate alias
        parse_join_graph(
            {"tables": [{"name": "a"}, {"name": "a"}], "edges": []}
        )
    with pytest.raises(ValueError):  # self-join
        parse_join_graph({
            "tables": [{"name": "a"}],
            "edges": [{"left": "a", "left_column": "x",
                       "right": "a", "right_column": "y"}],
        })
    with pytest.raises(ValueError):  # filter selectivity out of range
        parse_join_graph(
            {"tables": [{"name": "a", "filter_selectivity": 0.0}],
             "edges": []}
        )
    with pytest.raises(ValueError):  # namespace without dataset
        parse_join_graph(
            {"tables": [{"name": "a", "namespace": "wh"}], "edges": []}
        )


def test_identity_is_listing_order_insensitive():
    a = parse_join_graph({
        "tables": [{"name": "x"}, {"name": "y"}],
        "edges": [{"left": "x", "left_column": "k",
                   "right": "y", "right_column": "j"}],
    })
    b = parse_join_graph({
        "tables": [{"name": "y"}, {"name": "x"}],
        # the same edge, written from the other side
        "edges": [{"left": "y", "left_column": "j",
                   "right": "x", "right_column": "k"}],
    })
    assert a.identity() == b.identity()


def test_parse_max_plans():
    assert parse_max_plans(None) == DEFAULT_MAX_PLANS
    assert parse_max_plans(10) == 10
    assert parse_max_plans(10**9) == 65536  # ceiling
    for junk in (0, -1, 1.5, "many"):
        with pytest.raises(ValueError):
            parse_max_plans(junk)


# -- enumeration --------------------------------------------------------------


def test_enumeration_exhaustive_and_lexicographic():
    plans = enumerate_plans(4, DEFAULT_MAX_PLANS)
    assert plans.shape == (24, 4)
    assert [int(x) for x in plans[0]] == [0, 1, 2, 3]
    assert len({tuple(int(x) for x in p) for p in plans}) == 24
    # lexicographic order — itertools.permutations contract
    as_tuples = [tuple(int(x) for x in p) for p in plans]
    assert as_tuples == sorted(as_tuples)


def test_enumeration_sampled_deterministic():
    assert plan_space_size(7) == math.factorial(7) == 5040
    a = enumerate_plans(7, 1000)
    b = enumerate_plans(7, 1000)
    assert a.shape == (1000, 7)
    assert np.array_equal(a, b)  # seed-pinned
    assert [int(x) for x in a[0]] == list(range(7))  # identity first
    assert len({tuple(int(x) for x in p) for p in a}) == 1000  # deduped


# -- cost model edge cases ----------------------------------------------------


def test_zero_ndv_clamps_to_passthrough():
    g = _graph(2, [(0, 1)])
    stats = _stats(g, {"t0": 100, "t1": 200}, {"t0": 0.0, "t1": -3.0})
    body = compute_cost(g, stats, mode="paper", max_plans=16)
    join = body["joins"][0]
    edge = join["edges"][0]
    assert edge["ndv_left"] == 1.0 and edge["ndv_right"] == 1.0
    assert edge["selectivity"] == 1.0
    assert join["cardinality"] == 100.0 * 200.0  # |R||S| / max(1,1)


def test_cross_product_step_flagged_and_unfiltered():
    # Chain t0 - t1 - t2: the plan (t0, t2, t1) joins t2 with no edge to
    # the {t0} prefix — a cross product, multiplier exactly 1.
    g = _graph(3, [(0, 1), (1, 2)])
    rows = np.array([10.0, 20.0, 30.0], dtype=np.float32)
    factors = [(0, 1, 0.5), (1, 2, 0.25)]
    plan = [0, 2, 1]
    cost, cards = reference_cost(plan, rows, factors)
    assert cards[0] == np.float32(10.0 * 30.0)  # no selectivity applied
    # step 2 brings t1, connected to both t0 and t2: both edges fire
    assert cards[1] == np.float32(
        np.float32(np.float32(cards[0] * np.float32(20.0)) *
                   np.float32(np.float32(0.5) * np.float32(0.25)))
    )
    # the served body flags the cross-product step
    stats = _stats(g, {"t0": 10, "t1": 20, "t2": 30},
                   {"t0": 2, "t1": 2, "t2": 4})
    body = compute_cost(g, stats, mode="paper", max_plans=16)
    flagged = {j["table"]: j["cross_product"] for j in body["joins"]}
    assert flagged and not all(flagged.values())  # best order avoids them
    assert all(j["edges"] == [] for j in body["joins"]
               if j["cross_product"])


def test_tie_break_is_lexicographic_smallest_plan():
    # Perfectly symmetric 3-clique: every order costs the same, so the
    # winner must be the identity permutation — deterministically.
    g = _graph(3, [(0, 1), (0, 2), (1, 2)])
    stats = _stats(g, {t.name: 100 for t in g.tables},
                   {t.name: 10 for t in g.tables})
    for _ in range(3):
        body = compute_cost(g, stats, mode="paper", max_plans=16)
        assert body["best_order"] == ["t0", "t1", "t2"]


def test_best_order_prefers_selective_join_first():
    # t0 join t1 (on a, NDV 1000) is highly selective; t0 join t2 (on b,
    # NDV 2) barely filters. C_out must schedule the selective join first.
    g = parse_join_graph({
        "tables": [{"name": "t0"}, {"name": "t1"}, {"name": "t2"}],
        "edges": [
            {"left": "t0", "left_column": "a",
             "right": "t1", "right_column": "k"},
            {"left": "t0", "left_column": "b",
             "right": "t2", "right_column": "k"},
        ],
    })
    stats = {
        "t0": TableStats(rows=1000.0, columns={
            "a": ColumnStats(ndv=1000.0, non_null=1),
            "b": ColumnStats(ndv=2.0, non_null=1)}),
        "t1": TableStats(rows=1000.0, columns={
            "k": ColumnStats(ndv=1000.0, non_null=1)}),
        "t2": TableStats(rows=1000.0, columns={
            "k": ColumnStats(ndv=2.0, non_null=1)}),
    }
    body = compute_cost(g, stats, mode="paper", max_plans=16)
    assert body["best_order"].index("t1") < body["best_order"].index("t2")


# -- batched / reference parity (bit-for-bit) ---------------------------------


def _random_connected_graph(rng, n):
    edges = [(int(rng.integers(0, i)), i) for i in range(1, n)]  # spanning
    extra = rng.integers(0, n * (n - 1) // 2 - (n - 1) + 1) if n > 2 else 0
    seen = set(edges)
    for _ in range(int(extra)):
        a, b = sorted(rng.choice(n, size=2, replace=False).tolist())
        if (a, b) not in seen:
            seen.add((a, b))
            edges.append((a, b))
    return _graph(n, edges)


@pytest.mark.parametrize("seed", range(6))
def test_batched_scorer_matches_reference_bit_for_bit(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 8))
    g = _random_connected_graph(rng, n)
    rows = {t.name: float(rng.integers(10, 10**6)) for t in g.tables}
    ndv = {t.name: float(rng.integers(1, 10**4)) for t in g.tables}
    stats = _stats(g, rows, ndv)

    ref_costs, plans = sequential_reference(g, stats, max_plans=256)

    index = {name: i for i, name in enumerate(g.names)}
    base_rows = np.array(
        [np.float32(rows[name]) for name in g.names], dtype=np.float32
    )
    factors = []
    for e in g.edges:
        f = float(np.float32(1.0) / np.float32(
            max(max(1.0, ndv[e.left]), max(1.0, ndv[e.right]))
        ))
        factors.append((index[e.left], index[e.right], f))
    costs, cards = score_plans(plans, base_rows, factors)

    assert costs.dtype == np.float32
    assert costs.tobytes() == ref_costs.tobytes(), (
        f"seed={seed} n={n}: batched scorer diverged from reference"
    )
    # per-step cardinalities too, for every plan
    for p in range(plans.shape[0]):
        _, ref_cards = reference_cost(
            [int(x) for x in plans[p]], base_rows, factors
        )
        assert cards[p].tobytes() == np.asarray(
            ref_cards, dtype=np.float32
        ).tobytes()
