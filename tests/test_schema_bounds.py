"""Schema constraints (§7.3) + multi-file dataset estimation."""
import numpy as np

from repro.columnar import (
    column_metadata_from_footer,
    dataset_column_metadata,
    read_footer,
    write_dataset,
    write_file,
)
from repro.columnar.generator import int_domain, uniform_column
from repro.columnar.writer import WriterOptions
from repro.core import estimate_columns


def test_fk_schema_bound_caps_estimate(tmp_path):
    """FK column: ndv <= row_count(referenced table) (Eq in §7.3)."""
    dom = int_domain(5000, seed=1)
    vals, truth = uniform_column(dom, 1 << 15, seed=2)
    write_file(str(tmp_path / "f"), {"fk": vals},
               options=WriterOptions(row_group_size=2048))
    meta = column_metadata_from_footer(read_footer(str(tmp_path / "f")), "fk")
    unbounded = estimate_columns([meta])[0]
    bounded = estimate_columns([meta], schema_bounds=[100.0])[0]
    assert bounded.ndv <= 100.0
    assert unbounded.ndv > 100.0


def test_multi_file_dataset_metadata(tmp_path):
    dom = int_domain(800, seed=3)
    shards = []
    for i in range(3):
        vals, _ = uniform_column(dom, 1 << 14, seed=4 + i)
        shards.append({"c": vals})
    write_dataset(str(tmp_path), shards,
                  options=WriterOptions(row_group_size=2048))
    metas = dataset_column_metadata(str(tmp_path), "c")
    assert len(metas) == 3
    # estimating per file then combining conservatively: max is a lower
    # bound of global ndv; each file alone should already be close
    ests = estimate_columns(metas, mode="improved")
    for e in ests:
        assert abs(e.ndv - 800) / 800 < 0.1, e
