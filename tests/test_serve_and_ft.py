"""Serving engine + fault-tolerance coordinator behaviour tests."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.coordinator import Coordinator, FaultEvent, FaultPlan
from repro.models import params as MP
from repro.models import registry
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_engine():
    cfg = registry.get_smoke_config("qwen3_0_6b").scaled(
        dtype="float32", param_dtype="float32",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    )
    model = registry.build_model(cfg)
    params = MP.init_params(model.specs(), jax.random.PRNGKey(0), jnp.float32)
    return ServeEngine(model, cfg, params, slots=2, cache_len=64), cfg


def test_engine_completes_all_requests(small_engine):
    engine, cfg = small_engine
    reqs = [
        Request(rid=i, prompt=[3, 5, 7], max_new_tokens=4) for i in range(5)
    ]
    done = engine.run(reqs)
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.generated)


def test_engine_greedy_deterministic(small_engine):
    engine, cfg = small_engine
    a = engine.run([Request(rid=0, prompt=[2, 4, 6], max_new_tokens=6)])
    b = engine.run([Request(rid=1, prompt=[2, 4, 6], max_new_tokens=6)])
    assert a[0].generated == b[0].generated


# --- coordinator -----------------------------------------------------------


def test_failure_detection():
    c = Coordinator(4, miss_threshold=2)
    c.workers[3].missed = 2
    dead = c.dead_workers()
    assert dead == [3]
    assert c.alive_workers() == [0, 1, 2]


def test_straggler_eviction_needs_patience():
    c = Coordinator(4, straggler_factor=1.5, patience=3)
    for w in range(4):
        c.workers[w].step_ewma = 1.0
    c.workers[2].step_ewma = 5.0
    out = []
    for _ in range(3):
        out = c.stragglers()
    assert out == [2]
    assert 2 not in c.alive_workers()


def test_fault_plan_recover():
    c = Coordinator(3)
    plan = FaultPlan(events=[
        FaultEvent(step=1, kind="fail", worker_id=1),
        FaultEvent(step=5, kind="recover", worker_id=1),
    ])
    assert c.apply_plan(plan, 1)
    assert c.alive_workers() == [0, 2]
    assert c.apply_plan(plan, 5)
    assert c.alive_workers() == [0, 1, 2]


def test_elastic_batch_split():
    from repro.ft.coordinator import elastic_batch_split

    assert elastic_batch_split(256, alive=3, total=4) == 192
    assert elastic_batch_split(256, alive=4, total=4) == 256
