"""Stats service: async ingestion, ETag coherence, single-flight, HTTP e2e.

Covers the serving-correctness acceptance criteria:
  * /estimate responses are bit-identical to `StatsCatalog.estimate()` for
    the same engine config (reconstructed through `estimate_from_json`)
  * If-None-Match hits are answered with 304 and perform zero packs and
    zero engine executions (asserted by counters)
  * rewriting one file rotates the ETag; the old tag stops validating
  * N concurrent identical cold requests coalesce onto one engine pack
  * `AsyncIngestor` overlaps footer reads and keeps the last-good merged
    state serving while a refresh is blocked mid-flight
"""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.catalog import StatsCatalog, estimate_from_json
from repro.catalog.source import InMemoryMetadataSource
from repro.columnar.writer import WriterOptions, write_file
from repro.service import (
    AsyncIngestor,
    SingleFlight,
    StatsServer,
    StatsService,
    etag_matches,
    fetch_json,
    parse_bounds,
)


def _shard(seed, rows=256, vocab=64):
    rng = np.random.default_rng(seed)
    return {
        "tok": rng.integers(0, vocab, rows).astype(np.int64),
        "val": np.round(rng.uniform(0, 100, rows), 1),
    }


def _write(root, name, seed):
    return write_file(
        os.path.join(root, name), _shard(seed),
        options=WriterOptions(row_group_size=128),
    )


def _footer(seed, cols=None):
    return write_file(
        tempfile.mkdtemp(), cols if cols is not None else _shard(seed),
        options=WriterOptions(row_group_size=128),
    )


@pytest.fixture()
def dataset(tmp_path):
    root = str(tmp_path / "ds")
    for i in range(3):
        _write(root, f"shard_{i:03d}", seed=i)
    return root


@pytest.fixture()
def served(dataset):
    server = StatsServer(StatsService(dataset)).start()
    yield server
    server.stop()


# -- HTTP end-to-end ---------------------------------------------------------


def test_estimate_bit_identical_to_catalog(served, dataset):
    for mode in ("paper", "improved"):
        status, etag, body = fetch_json(served.url + f"/estimate?mode={mode}")
        assert status == 200 and etag and body["etag"] == etag
        got = {n: estimate_from_json(d) for n, d in body["estimates"].items()}
        ref = StatsCatalog(dataset).estimate(mode=mode)
        assert got == ref  # dataclass equality: every field, bit-exact


def test_revalidation_304_zero_packs_zero_engine_runs(served):
    url = served.url + "/estimate"
    svc = served.service
    status, etag, _ = fetch_json(url)
    assert status == 200
    packs = svc.catalog.stats.packs
    runs = svc.stats.engine_runs
    misses = svc.catalog.stats.estimate_cache_misses
    for _ in range(3):
        status2, etag2, body = fetch_json(url, etag=etag)
        assert status2 == 304 and etag2 == etag and body is None
    assert svc.catalog.stats.packs == packs
    assert svc.stats.engine_runs == runs
    assert svc.catalog.stats.estimate_cache_misses == misses
    assert svc.stats.responses_304 == 3


def test_etag_rotates_on_rewrite_and_old_tag_stops_validating(served, dataset):
    url = served.url + "/estimate?mode=improved"
    _, etag1, body1 = fetch_json(url)
    assert fetch_json(url, etag=etag1)[0] == 304

    _write(dataset, "shard_001", seed=77)  # rewrite one existing file
    status, refreshed = fetch_json(served.url + "/refresh", method="POST")[0:3:2]
    assert status == 200
    assert refreshed["updated"] == 1 and refreshed["changed"]

    status, etag2, body2 = fetch_json(url, etag=etag1)  # old tag must NOT validate
    assert status == 200 and etag2 != etag1
    assert body2["estimates"] != body1["estimates"]
    assert body2["generation"] > body1["generation"]
    assert fetch_json(url, etag=etag2)[0] == 304
    # the commit compacted entries of the dead fingerprint set
    assert len(served.service.catalog._estimate_cache) <= 1


def test_etag_covers_mode_and_bounds_and_endpoint(served):
    tags = {
        fetch_json(served.url + path)[1]
        for path in (
            "/estimate?mode=paper",
            "/estimate?mode=improved",
            "/estimate?mode=paper&bounds=tok:10",
            "/plan?mode=paper",
            "/columns",
        )
    }
    assert len(tags) == 5  # every request identity gets its own tag


def test_schema_bounds_and_plan_match_library(served, dataset):
    _, _, body = fetch_json(served.url + "/estimate?bounds=tok:10")
    ref = StatsCatalog(dataset).estimate(schema_bounds={"tok": 10.0})
    got = {n: estimate_from_json(d) for n, d in body["estimates"].items()}
    assert got == ref and got["tok"].ndv <= 10.0

    _, _, plans = fetch_json(served.url + "/plan?mode=improved")
    import dataclasses

    ref_plans = StatsCatalog(dataset).plan(mode="improved")
    assert plans["plans"] == {
        n: dataclasses.asdict(p) for n, p in ref_plans.items()
    }


def test_columns_health_and_errors(served):
    status, etag, body = fetch_json(served.url + "/columns")
    assert status == 200 and set(body["columns"]) == {"tok", "val"}
    assert body["files"] == 3
    assert fetch_json(served.url + "/columns", etag=etag)[0] == 304

    status, _, health = fetch_json(served.url + "/health")
    assert status == 200 and health["status"] == "serving"
    assert health["files"] == 3 and health["generation"] == 1

    assert fetch_json(served.url + "/estimate?mode=bogus")[0] == 400
    assert fetch_json(served.url + "/nope")[0] == 404
    assert fetch_json(served.url + "/estimate?bounds=junk")[0] == 400


def test_concurrent_identical_cold_requests_one_engine_pack(served, dataset):
    svc = served.service
    url = served.url + "/estimate"
    fetch_json(url)  # settle jit/tracing so the patched sleep dominates

    _write(dataset, "shard_new", seed=50)  # rotate state -> next req is cold
    svc.refresh()
    orig = svc.catalog.estimate

    def slow_estimate(**kw):
        time.sleep(0.5)
        return orig(**kw)

    svc.catalog.estimate = slow_estimate
    try:
        packs = svc.catalog.stats.packs
        runs = svc.stats.engine_runs
        n = 8
        barrier = threading.Barrier(n)
        results = []

        def client():
            barrier.wait()
            results.append(fetch_json(url)[0])

        threads = [threading.Thread(target=client) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        svc.catalog.estimate = orig
    assert results == [200] * n
    assert svc.catalog.stats.packs - packs == 1       # ONE pack
    assert svc.stats.engine_runs - runs == 1          # ONE engine execution
    assert svc.stats.coalesced_waits >= 1             # real coalescing seen
    assert svc.stats.single_flight_leaders >= 1


# -- single-flight unit ------------------------------------------------------


def test_single_flight_coalesces_and_propagates_errors():
    flight = SingleFlight()
    entered = threading.Event()
    release = threading.Event()
    calls = []

    def fn():
        calls.append(1)
        entered.set()
        release.wait(5)
        return "value"

    out = []
    threads = [
        threading.Thread(target=lambda: out.append(flight.do(("k",), fn)))
        for _ in range(5)
    ]
    threads[0].start()
    assert entered.wait(5)
    for t in threads[1:]:
        t.start()
    time.sleep(0.05)  # let followers reach the wait
    release.set()
    for t in threads:
        t.join()
    assert len(calls) == 1
    assert [r for r, _ in out] == ["value"] * 5
    assert sorted(leader for _, leader in out) == [False] * 4 + [True]

    def boom():
        raise RuntimeError("nope")

    with pytest.raises(RuntimeError, match="nope"):
        flight.do(("k2",), boom)


def test_etag_matches_and_parse_bounds():
    assert etag_matches('"abc"', '"abc"')
    assert etag_matches('W/"abc"', '"abc"')
    assert etag_matches('"x", "abc"', '"abc"')
    assert etag_matches("*", '"anything"')
    assert not etag_matches('"x"', '"abc"')
    assert parse_bounds("tok:10,val:2.5") == {"tok": 10.0, "val": 2.5}
    with pytest.raises(ValueError):
        parse_bounds("junk")


# -- async ingestor ----------------------------------------------------------


class SlowSource(InMemoryMetadataSource):
    """InMemory source with configurable footer-read latency and a gate."""

    def __init__(self, footers, read_delay=0.0):
        super().__init__(footers)
        self.read_delay = read_delay
        self.gate = None  # when set, read_footer blocks until released

    def read_footer(self, file_id):
        if self.gate is not None:
            assert self.gate.wait(10)
        if self.read_delay:
            time.sleep(self.read_delay)
        return super().read_footer(file_id)


def test_ingestor_overlaps_footer_reads():
    n, delay = 6, 0.15
    src = SlowSource(
        {f"f{i}": _footer(seed=i) for i in range(n)}, read_delay=delay
    )
    ingestor = AsyncIngestor(StatsCatalog(src), max_workers=n)
    t0 = time.perf_counter()
    summary = ingestor.refresh()
    wall = time.perf_counter() - t0
    assert summary.added == n
    assert ingestor.stats.footers_read == n
    # serial would be >= n * delay; overlapped must beat half of that
    assert wall < 0.5 * n * delay, f"reads did not overlap: {wall:.2f}s"


def test_last_good_state_serves_during_inflight_refresh():
    src = SlowSource({"a": _footer(1), "b": _footer(2)})
    svc = StatsService(src)
    svc.start()
    r1 = svc.estimate(mode="paper")
    assert r1.status == 200 and svc.ingestor.generation == 1

    src.add("c", _footer(3))
    src.gate = threading.Event()  # block the refresh mid-footer-read
    t = threading.Thread(target=svc.refresh)
    t.start()
    time.sleep(0.1)  # refresh is now parked inside read_footer
    r2 = svc.estimate(mode="paper")  # must not block, must serve old state
    assert r2.status == 200 and r2.etag == r1.etag
    assert r2.body["estimates"] == r1.body["estimates"]
    assert svc.estimate(mode="paper", if_none_match=r1.etag).status == 304
    src.gate.set()
    t.join(10)
    assert svc.ingestor.generation == 2
    r3 = svc.estimate(mode="paper")
    assert r3.etag != r1.etag and r3.body["generation"] == 2


def test_refresh_error_keeps_last_good_and_records_it():
    src = SlowSource({"a": _footer(1), "b": _footer(2)})
    svc = StatsService(src)
    svc.start()
    before = svc.estimate(mode="paper")
    src.add("bad", _footer(9, cols={"other": np.arange(64)}))
    with pytest.raises(ValueError, match="schema"):
        svc.refresh()
    assert svc.ingestor.stats.errors == 1
    assert "schema" in svc.ingestor.stats.last_error
    assert svc.ingestor.generation == 1  # no commit
    after = svc.estimate(mode="paper", if_none_match=before.etag)
    assert after.status == 304  # last-good still validates


def test_ingestor_add_remove_rewrite_in_one_refresh():
    src = InMemoryMetadataSource(
        {"a": _footer(1), "b": _footer(2), "c": _footer(3)}
    )
    catalog = StatsCatalog(src)
    ingestor = AsyncIngestor(catalog)
    assert ingestor.refresh().added == 3
    src.add("d", _footer(4))       # add
    src.remove("b")                # remove
    src.add("c", _footer(33))      # rewrite
    summary = ingestor.refresh()
    assert summary == (1, 1, 1, 3)  # added, updated, removed, total
    assert set(catalog.files) == {"a", "c", "d"}
    # the committed view matches a cold catalog over the same source
    assert catalog.estimate() == StatsCatalog(src).estimate()


def test_server_stop_after_failed_start_does_not_hang(tmp_path):
    root = str(tmp_path / "bad")
    _write(root, "a", seed=1)
    write_file(  # schema-mismatched file: the initial refresh must raise
        os.path.join(root, "b"), {"other": np.arange(64)},
        options=WriterOptions(row_group_size=32),
    )
    server = StatsServer(StatsService(root))
    with pytest.raises(ValueError, match="schema"):
        server.start()
    server.stop()  # accept loop never ran; must return, not block


def test_save_cache_on_commit_keeps_spill_warm(dataset):
    svc = StatsService(dataset, save_cache_on_commit=True)
    with svc:
        r = svc.estimate(mode="improved")
        _write(dataset, "shard_new", seed=9)
        svc.refresh()   # commit rewrites the spill (compacted, now empty)
        r2 = svc.estimate(mode="improved")  # cold compute re-spills
        assert r2.etag != r.etag
    warm = StatsCatalog(dataset, auto_load_cache=True)
    got = warm.estimate(mode="improved")
    assert warm.stats.packs == 0            # restart serves the spill
    assert got == {
        n: estimate_from_json(d) for n, d in r2.body["estimates"].items()
    }


def test_polling_loop_picks_up_changes_and_stops():
    src = InMemoryMetadataSource({"a": _footer(1)})
    svc = StatsService(src, poll_interval=0.05)
    svc.start()
    try:
        assert svc.ingestor.running
        src.add("b", _footer(2))
        deadline = time.time() + 10
        while svc.ingestor.generation < 2 and time.time() < deadline:
            time.sleep(0.02)
        assert svc.ingestor.generation == 2
    finally:
        svc.stop()
    assert not svc.ingestor.running


# -- estimation-quality observability: explain + audit (ISSUE 9) --------------


def test_explain_attaches_provenance_without_perturbing_identity(served):
    """?explain=1: same ETag, body copy + provenance — never a new identity."""
    status, etag, plain = fetch_json(served.url + "/estimate?mode=improved")
    assert status == 200
    status, etag_e, explained = fetch_json(
        served.url + "/estimate?mode=improved&explain=1"
    )
    assert status == 200
    assert etag_e == etag, "explain must not rotate the ETag"
    assert explained["provenance"].keys() == plain["estimates"].keys()
    stripped = {k: v for k, v in explained.items() if k != "provenance"}
    assert stripped == plain, "explained body minus provenance != plain body"
    for prov in explained["provenance"].values():
        assert prov["route"] in ("dict", "minmax")
        assert isinstance(prov["dict_iterations"], int)
        assert isinstance(prov["clamps"], list)
    # the old ETag still revalidates the explained URL (same identity)
    status, _, _ = fetch_json(
        served.url + "/estimate?mode=improved&explain=1", etag=etag
    )
    assert status == 304


def test_explain_does_not_mutate_cached_plain_body(served):
    status, _, _ = fetch_json(served.url + "/estimate?mode=paper&explain=1")
    assert status == 200
    status, _, plain = fetch_json(served.url + "/estimate?mode=paper")
    assert status == 200
    assert "provenance" not in plain, (
        "explain leaked into the cached response body"
    )


def test_explain_junk_value_is_400(served):
    status, _, body = fetch_json(served.url + "/estimate?explain=banana")
    assert status == 400 and "error" in body
    # explicit falsy forms are accepted and behave like absence
    for off in ("0", "false", "no", ""):
        status, _, body = fetch_json(served.url + f"/estimate?explain={off}")
        assert status == 200 and "provenance" not in body


def test_explain_wire_frame_value_section_is_explain_blind(served):
    """Provenance rides section 4; the value section stays byte-stable."""
    from repro.wire import ConnectionPool, decode_explain, decode_frame, fetch

    pool = ConnectionPool()
    try:
        url = served.url + "/estimate?mode=improved"
        wire_headers = {"Accept": "application/x-ndv-wire"}
        _, _, raw_plain = pool.request(url, headers=wire_headers)
        _, _, raw_expl = pool.request(url + "&explain=1", headers=wire_headers)
        assert decode_frame(raw_expl) == decode_frame(raw_plain)
        assert decode_explain(raw_plain) is None
        status, _, body_json = fetch_json(url + "&explain=1")
        assert decode_explain(raw_expl) == body_json["provenance"]
        # the wire client re-attaches: wire and JSON bodies identical
        status, _, body_wire = fetch(url + "&explain=1", pool=pool, binary=True)
        assert status == 200 and body_wire == body_json
    finally:
        pool.close()


def test_audit_loop_records_qerror_and_rides_explain(dataset):
    from repro.obs import registry

    svc = StatsService(dataset, audit=True, audit_columns=8)
    svc.refresh()
    results = svc.run_audit()
    assert results, "audit produced no samples on a readable dataset"
    audited = {r.column for r in results}
    assert audited == {"tok", "val"}
    for r in results:
        assert r.qerror >= 1.0
        assert r.reference > 0
        assert r.route in ("dict", "minmax")
    resp = svc.estimate(mode="paper", explain=True)
    provs = resp.body["provenance"]
    assert any("audit" in p for p in provs.values())
    for name, p in provs.items():
        if "audit" in p:
            assert p["audit"]["qerror"] >= 1.0
    text = registry().exposition()
    assert "ndv_audit_qerror" in text and 'route="' in text


def test_explained_payload_not_stale_after_audit(dataset):
    """The memoized explained payload must refresh when the audit does."""
    with StatsServer(StatsService(dataset, audit=True)) as server:
        url = server.url + "/estimate?mode=improved&explain=1"
        status, _, before = fetch_json(url)
        assert status == 200
        assert not any("audit" in p for p in before["provenance"].values())
        server.service.run_audit()
        status, _, after = fetch_json(url)
        assert status == 200
        assert any("audit" in p for p in after["provenance"].values()), (
            "explained payload served stale (pre-audit) bytes"
        )


def test_debug_explain_serves_provenance_cache(served):
    fetch_json(served.url + "/estimate?mode=paper")
    fetch_json(served.url + "/estimate?mode=improved&explain=1")
    status, etag, body = fetch_json(served.url + "/debug/explain")
    assert status == 200 and etag is None
    modes = {e["mode"] for e in body["entries"]}
    assert "improved" in modes
    for entry in body["entries"]:
        for name, prov in entry["columns"].items():
            assert prov["route"] in ("dict", "minmax")


def test_debug_query_params_hardened(served):
    """Malformed /debug/* query values answer 400, never an unhandled 500."""
    for q in ("limit=-1", "limit=abc", "limit=", "limit=1.5"):
        status, _, body = fetch_json(served.url + f"/debug/traces?{q}")
        assert status == 400 and "error" in body, q
    status, _, _ = fetch_json(served.url + "/debug/traces?limit=0")
    assert status == 200
