"""Sharding rules, writer round-trips, columnar invariants (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.columnar import read_footer, write_file
from repro.columnar.reader import DataReader, column_metadata_from_footer
from repro.columnar.writer import WriterOptions, _ceil_log2


# --- columnar writer invariants ---------------------------------------------


@given(
    rows=st.integers(10, 3000),
    ndv=st.integers(1, 500),
    rg=st.sampled_from([64, 256, 1024]),
    seed=st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
def test_writer_metadata_invariants(tmp_path_factory, rows, ndv, rg, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, ndv, rows).astype(np.int64)
    d = tmp_path_factory.mktemp("wf")
    write_file(str(d / "f"), {"c": vals}, options=WriterOptions(row_group_size=rg))
    footer = read_footer(str(d / "f"))
    meta = column_metadata_from_footer(footer, "c")

    # row counts partition the file
    assert int(meta.chunk_rows.sum()) == rows
    # stats bracket the data per chunk
    reader = DataReader(str(d / "f"))
    for i in range(footer.num_row_groups):
        chunk = reader.read_row_group("c", i)
        assert meta.mins[i] == chunk.min()
        assert meta.maxs[i] == chunk.max()
        # Eq 1 exactness for dictionary-encoded chunks
        cm = footer.row_groups[i].columns["c"]
        if cm.dictionary_encoded:
            local = np.unique(chunk).size
            bits = _ceil_log2(local)
            expect = local * 8 + int(np.ceil(len(chunk) * bits / 8))
            assert cm.total_uncompressed_size == expect
    # distinct min/max counts match exact recomputation
    assert meta.distinct_min_count == np.unique(meta.mins).size
    assert meta.distinct_max_count == np.unique(meta.maxs).size


def test_estimate_never_exceeds_non_null(tmp_path):
    """Hybrid invariant (Eq 13): ndv <= N - nulls, any input."""
    rng = np.random.default_rng(1)
    vals = rng.integers(0, 50, 500).astype(np.int64)
    mask = rng.uniform(size=500) < 0.5
    write_file(str(tmp_path / "f"), {"c": vals}, null_masks={"c": mask},
               options=WriterOptions(row_group_size=100))
    from repro.core import estimate_columns

    meta = column_metadata_from_footer(read_footer(str(tmp_path / "f")), "c")
    for mode in ("paper", "improved"):
        est = estimate_columns([meta], mode=mode)[0]
        assert est.ndv <= meta.non_null + 1e-6


# --- sharding rule resolution -------------------------------------------------


def test_checked_sharding_drops_indivisible_and_dupes():
    import jax
    from repro.parallel.sharding import checked_sharding

    if jax.device_count() < 1:
        pytest.skip("no devices")
    # single-device mesh: every axis has size 1 -> all dropped
    mesh = jax.make_mesh((1,), ("model",))
    s = checked_sharding(mesh, (40, 512), ("experts", "ff"))
    assert all(a is None for a in s.spec)


def test_rules_for_seq_parallel_selection():
    import jax
    from repro.configs.shapes import get_shape
    from repro.launch.cells import rules_for
    from repro.models import registry

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    shape = get_shape("train_4k")
    r_qwen = rules_for(registry.get_config("qwen2_7b"), FakeMesh, shape)
    assert r_qwen["heads"] is None and r_qwen["seq_model"] == "model"
    r_seam = rules_for(registry.get_config("seamless_m4t_large_v2"), FakeMesh, shape)
    assert r_seam["heads"] == "model" and r_seam["seq_model"] is None
    r_mix = rules_for(registry.get_config("mixtral_8x22b"), FakeMesh, shape)
    assert r_mix["moe_seq"] is None  # big experts -> TP-gathered buffers
    r_gran = rules_for(registry.get_config("granite_moe_3b_a800m"), FakeMesh, shape)
    assert r_gran["ff"] is None      # small experts -> replicate over model

    dec = get_shape("decode_32k")
    r_dec = rules_for(registry.get_config("qwen2_7b"), FakeMesh, dec)
    assert r_dec["seq_sharded"] == ("model",)
