"""End-to-end behaviour tests: the paper's system as framework plumbing.

Covers the integration spine: generate columnar data -> metadata-only NDV
estimation -> planner -> data pipeline -> short training run with
checkpoint/restart + fault injection.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.planner import NDVPlanner
from repro.data.pipeline import DataConfig, TokenPipeline, synthesize_token_dataset
from repro.ft.coordinator import FaultEvent, FaultPlan
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.train_step import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("tokens"))
    synthesize_token_dataset(
        root, vocab_size=512, num_shards=2, rows_per_shard=1 << 14,
        row_group_size=2048,
    )
    return root


def test_pipeline_plans_from_metadata_only(dataset):
    pipe = TokenPipeline(DataConfig(root=dataset, batch_size=2, seq_len=64))
    est = pipe.vocab_estimate()
    assert est is not None
    # zipf over 512 tokens: skewed frequencies shrink per-chunk coverage
    # (characterized in benchmarks/accuracy.py) — the planning contract is
    # a sane same-order underestimate, never an overestimate blowup.
    assert 0.55 * 512 <= est.ndv <= 1.25 * 512, est
    plan = pipe.plan
    assert plan.total_staging_bytes > 0
    mem = plan.memory["tokens"]
    assert mem.d_batch_bytes <= mem.d_global_bytes + 1


def test_pipeline_deterministic_resume(dataset):
    cfg = DataConfig(root=dataset, batch_size=2, seq_len=64)
    a = list(TokenPipeline(cfg).batches(start_step=0))[:10]
    b = list(TokenPipeline(cfg).batches(start_step=5))[:5]
    for x, y in zip(a[5:], b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_planner_embedding_decisions():
    from repro.core.ndv.types import Layout, NDVEstimate

    planner = NDVPlanner(device_budget_bytes=1 << 20, num_model_shards=16)
    small = NDVEstimate(
        ndv=100, ndv_dict=100, ndv_minmax=90, layout=Layout.WELL_SPREAD,
        is_lower_bound=False, mean_len=4, len_sample_size=10,
        overlap_ratio=1.0, monotonicity=0.5, confidence=0.9, column_name="c",
    )
    p = planner.embedding_shard_plan(small, vocab_size=200, d_model=64)
    assert not p.shard_vocab  # tiny table fits
    big_active = NDVEstimate(
        ndv=1e6, ndv_dict=1e6, ndv_minmax=1e6, layout=Layout.WELL_SPREAD,
        is_lower_bound=False, mean_len=4, len_sample_size=10,
        overlap_ratio=1.0, monotonicity=0.5, confidence=0.9, column_name="c",
    )
    p2 = planner.embedding_shard_plan(big_active, vocab_size=1 << 20, d_model=1024)
    assert p2.shard_vocab and p2.num_shards > 1
    # high vocab but tiny ACTIVE set: prefer row-gather over vocab sharding
    tiny_active = NDVEstimate(
        ndv=50, ndv_dict=50, ndv_minmax=40, layout=Layout.WELL_SPREAD,
        is_lower_bound=False, mean_len=4, len_sample_size=10,
        overlap_ratio=1.0, monotonicity=0.5, confidence=0.9, column_name="c",
    )
    p3 = planner.embedding_shard_plan(tiny_active, vocab_size=1 << 20, d_model=1024)
    assert not p3.shard_vocab


def test_planner_pushdown():
    from repro.core.ndv.types import Layout, NDVEstimate

    planner = NDVPlanner()
    low = NDVEstimate(
        ndv=10, ndv_dict=10, ndv_minmax=10, layout=Layout.WELL_SPREAD,
        is_lower_bound=False, mean_len=8, len_sample_size=4,
        overlap_ratio=1.0, monotonicity=0.5, confidence=0.9, column_name="g",
    )
    assert planner.pushdown(low, 1e6).push_down
    lb = NDVEstimate(
        ndv=9e5, ndv_dict=9e5, ndv_minmax=1, layout=Layout.WELL_SPREAD,
        is_lower_bound=True, mean_len=8, len_sample_size=4,
        overlap_ratio=1.0, monotonicity=0.5, confidence=0.3, column_name="g",
    )
    assert not planner.pushdown(lb, 1e6).push_down


def test_train_checkpoint_restart_fault_plan(dataset, tmp_path):
    """Short training run, kill a worker mid-run, restart resumes LATEST."""
    cfg = registry.get_smoke_config("qwen3_0_6b").scaled(
        dtype="float32", param_dtype="float32", vocab_size=512
    )
    model = registry.build_model(cfg)
    pipe = TokenPipeline(DataConfig(root=dataset, batch_size=2, seq_len=64))
    tc = TrainerConfig(
        total_steps=6, ckpt_interval=2, ckpt_dir=str(tmp_path / "ck"),
        ckpt_async=False, log_interval=100, num_workers=4,
    )
    trainer = Trainer(
        model, cfg, opt.AdamWConfig(lr=1e-3),
        schedule=opt.cosine_schedule(2, 6), trainer_cfg=tc,
    )
    state = init_train_state(model, cfg)
    plan = FaultPlan(events=[FaultEvent(step=3, kind="fail", worker_id=2)])
    state, report = trainer.run(state, pipe.batches(epochs=10), fault_plan=plan)
    assert report.steps_run == 6
    assert report.restarts >= 1
    assert any("DEAD" in e for e in report.evictions)
    assert np.isfinite(report.final_loss)

    # fresh trainer resumes from the latest checkpoint
    trainer2 = Trainer(
        model, cfg, opt.AdamWConfig(lr=1e-3),
        schedule=opt.cosine_schedule(2, 6),
        trainer_cfg=TrainerConfig(
            total_steps=8, ckpt_interval=4, ckpt_dir=str(tmp_path / "ck"),
            ckpt_async=False, log_interval=100,
        ),
    )
    state2 = init_train_state(model, cfg)
    state2, report2 = trainer2.run(state2, pipe.batches(epochs=10), resume=True)
    assert report2.resumed_from == 6
    assert report2.steps_run == 2


def test_loss_decreases_on_tiny_model(dataset):
    cfg = registry.get_smoke_config("qwen3_0_6b").scaled(
        dtype="float32", param_dtype="float32", vocab_size=512,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    )
    model = registry.build_model(cfg)
    pipe = TokenPipeline(DataConfig(root=dataset, batch_size=4, seq_len=64))
    from repro.train.train_step import make_train_step

    step = jax.jit(make_train_step(
        model, cfg, opt.AdamWConfig(lr=3e-3, weight_decay=0.0),
        schedule=lambda s: jnp.float32(1.0),
    ))
    state = init_train_state(model, cfg)
    losses = []
    for i, batch in enumerate(pipe.batches(epochs=5)):
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m.loss))
        if i >= 30:
            break
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
