"""Binary wire codec + connection pool: framing, JSON parity, negotiation.

The codec's whole contract is `decode_frame(encode_frame(x)) ==
json.loads(json.dumps(x))` — byte-level compactness is allowed to vary,
decoded semantics are not. These tests pin that equivalence (including
float bit-exactness and JSON's dict-key coercion), the malformed-input
behavior (every truncation/corruption answers `WireError`, never a raw
struct/index error), and the live-server guarantees: content negotiation
yields bit-identical bodies with byte-identical ETags across encodings,
and the keep-alive `ConnectionPool` reuses sockets and survives stale
keep-alives via a one-shot retry.
"""
import json
import math
import os
import struct

import numpy as np
import pytest

from repro.columnar.writer import WriterOptions, write_file
from repro.service import StatsServer, StatsService, fetch_json
from repro.wire import (
    JSON_CONTENT_TYPE,
    WIRE_CONTENT_TYPE,
    ConnectionPool,
    WireError,
    decode_frame,
    encode_frame,
    fetch,
)


def _json_roundtrip(x):
    return json.loads(json.dumps(x))


PAYLOADS = [
    None,
    True,
    False,
    0,
    -1,
    63,
    64,
    -64,
    -65,
    2**70,
    -(2**70),
    0.0,
    -1.5,
    1e308,
    "",
    "héllo\x00wörld",
    [],
    {},
    [1, "two", None, [3.0, {"k": False}]],
    {"a": 1, "b": [1.0, 2.0, 3.0], "c": {"nested": "yes"}},
    {"strings": ["a", "b", "a", "b", "a"]},
    # table-shaped: dict-of-dicts sharing one key sequence (the /estimate
    # body shape the 0x0A section exists for)
    {
        f"col{i}": {"ndv": float(i), "lo": -i, "hi": i * 2, "ok": i % 2 == 0}
        for i in range(8)
    },
    # ragged rows: must fall back to plain dict encoding, still roundtrip
    {"a": {"x": 1, "y": 2}, "b": {"x": 1}, "c": {"y": 2, "x": 1}},
]


@pytest.mark.parametrize("payload", PAYLOADS, ids=range(len(PAYLOADS)))
def test_roundtrip_matches_json_semantics(payload):
    assert decode_frame(encode_frame(payload)) == _json_roundtrip(payload)


def test_float_bits_exact():
    for v in (0.0, -0.0, 1e-300, -1e308, math.inf, -math.inf, math.pi):
        (out,) = decode_frame(encode_frame([v]))
        assert struct.pack("<d", out) == struct.pack("<d", v)
    (out,) = decode_frame(encode_frame([math.nan]))
    assert math.isnan(out)


def test_dict_key_coercion_matches_json():
    # json.dumps coerces non-str keys; the codec must match it exactly so
    # JSON and binary decode to the same dict.
    payload = {1: "int", 2.5: "float", True: "bool", None: "none"}
    assert decode_frame(encode_frame(payload)) == _json_roundtrip(payload)


def test_dict_key_collision_is_wire_error():
    # {"1": ..., 1: ...} silently collapses in json.dumps (last wins by
    # insertion order); the codec refuses instead of guessing.
    with pytest.raises(WireError):
        encode_frame({"1": "str", 1: "int"})


def test_table_shape_beats_json_size():
    body = {
        "estimates": {
            f"column_{i:04d}": {
                "ndv": float(i * 7), "low": 0.0, "high": float(i),
                "mode": "paper", "bounded": i % 3 == 0,
            }
            for i in range(256)
        }
    }
    frame = encode_frame(body)
    assert decode_frame(frame) == _json_roundtrip(body)
    assert len(frame) < len(json.dumps(body).encode())


def test_every_truncation_is_a_clean_wire_error():
    frame = encode_frame(PAYLOADS[-2])
    for cut in range(len(frame)):
        with pytest.raises(WireError):
            decode_frame(frame[:cut])


def test_bad_magic_and_version():
    frame = encode_frame({"a": 1})
    with pytest.raises(WireError):
        decode_frame(b"XXXX" + frame[4:])
    with pytest.raises(WireError):
        decode_frame(frame[:4] + bytes([frame[4] + 1]) + frame[5:])
    with pytest.raises(WireError):
        decode_frame(b"")


def test_corrupted_utf8_is_a_wire_error():
    frame = bytearray(encode_frame(["abcd"]))
    i = frame.index(b"abcd")
    frame[i:i + 4] = b"\xff\xfe\xfd\xfc"
    with pytest.raises(WireError):
        decode_frame(bytes(frame))


def test_hypothesis_roundtrip_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    scalars = st.one_of(
        st.none(),
        st.booleans(),
        st.integers(min_value=-(2**80), max_value=2**80),
        st.floats(allow_nan=False),  # NaN != NaN breaks == comparison only
        st.text(max_size=20),
    )
    jsonish = st.recursive(
        scalars,
        lambda children: st.one_of(
            st.lists(children, max_size=6),
            st.dictionaries(st.text(max_size=8), children, max_size=6),
        ),
        max_leaves=25,
    )

    @given(jsonish)
    @settings(max_examples=150, deadline=None)
    def roundtrip(payload):
        assert decode_frame(encode_frame(payload)) == _json_roundtrip(payload)

    roundtrip()


# -- live server: negotiation + pooling ---------------------------------------


def _write(root, name, seed):
    rng = np.random.default_rng(seed)
    return write_file(
        os.path.join(root, name),
        {
            "tok": rng.integers(0, 64, 512).astype(np.int64),
            "val": np.round(rng.uniform(0, 100, 512), 1),
        },
        options=WriterOptions(row_group_size=128),
    )


@pytest.fixture()
def server(tmp_path):
    root = str(tmp_path / "ds")
    for i in range(2):
        _write(root, f"shard_{i:03d}", seed=i)
    with StatsServer(StatsService(root)) as srv:
        yield srv


def test_binary_and_json_decode_bit_identical(server):
    pool = ConnectionPool()
    sj, ej, bj = fetch(server.url + "/estimate", pool=pool, binary=False)
    sw, ew, bw = fetch(server.url + "/estimate", pool=pool, binary=True)
    assert (sj, sw) == (200, 200)
    assert ej == ew                      # byte-identical ETags
    assert bj == bw                      # bit-identical decoded bodies
    # and both agree with the plain urllib JSON client
    s2, e2, b2 = fetch_json(server.url + "/estimate")
    assert (s2, e2, b2) == (200, ej, bj)


def test_binary_revalidation_304(server):
    pool = ConnectionPool()
    _, etag, _ = fetch(server.url + "/estimate", pool=pool, binary=True)
    status, etag2, body = fetch(
        server.url + "/estimate", pool=pool, etag=etag, binary=True
    )
    assert (status, etag2, body) == (304, etag, None)


def test_pool_reuses_connections(server):
    pool = ConnectionPool()
    for _ in range(4):
        status, _, _ = fetch(server.url + "/health", pool=pool)
        assert status == 200
    snap = pool.stats.snapshot()
    assert snap["opened"] == 1
    assert snap["reused"] == 3
    pool.close()


def test_pool_retries_stale_keepalive(server):
    pool = ConnectionPool()
    status, _, _ = fetch(server.url + "/health", pool=pool)
    assert status == 200
    # Sabotage the parked socket: the next request hits a dead keep-alive
    # connection and must transparently retry on a fresh one.
    key = (server.host, server.port)
    with pool._lock:
        for conn in pool._idle[key]:
            conn.sock.close()
    status, _, body = fetch(server.url + "/health", pool=pool)
    assert status == 200 and body["status"] == "serving"
    assert pool.stats.snapshot()["retried_stale"] >= 1


def test_wire_content_type_header(server):
    pool = ConnectionPool()
    status, headers, raw = pool.request(
        server.url + "/health",
        headers={"Accept": WIRE_CONTENT_TYPE},
    )
    assert status == 200
    assert headers["content-type"] == WIRE_CONTENT_TYPE
    assert decode_frame(raw)["status"] == "serving"
    status, headers, raw = pool.request(server.url + "/health", headers={})
    assert headers["content-type"] == JSON_CONTENT_TYPE
    assert json.loads(raw)["status"] == "serving"


# -- explain sidecar section (ISSUE 9) ----------------------------------------


def _value_section(frame: bytes) -> bytes:
    from repro.wire.codec import _SECTION_VALUE, _scan_sections

    _, sections = _scan_sections(frame)
    lo, hi = sections[_SECTION_VALUE]
    return frame[lo:hi]


def test_explain_section_leaves_value_section_bytes_unchanged():
    """The explain sidecar must be invisible to the value a peer decodes."""
    from repro.wire import decode_explain

    body = {"estimates": {"k": {"ndv": 12.5}}, "meta": [1, 2, 3]}
    prov = {"k": {"route": "dict", "route_margin": 3.25, "clamps": []}}
    plain = encode_frame(body)
    explained = encode_frame(body, explain=prov)
    assert explained != plain                      # the section is really there
    assert _value_section(explained) == _value_section(plain)
    # old peers: decode_frame of an explained frame is just the value
    assert decode_frame(explained) == decode_frame(plain) == _json_roundtrip(body)
    assert decode_explain(explained) == _json_roundtrip(prov)


def test_decode_explain_is_best_effort():
    """No section -> None; a garbled section -> None, never an exception."""
    from repro.wire import decode_explain
    from repro.wire.codec import _SECTION_EXPLAIN, _scan_sections

    plain = encode_frame({"a": 1})
    assert decode_explain(plain) is None

    explained = bytearray(encode_frame({"a": 1}, explain={"p": "x"}))
    _, sections = _scan_sections(bytes(explained))
    lo, hi = sections[_SECTION_EXPLAIN]
    for i in range(lo, hi):                        # corrupt every byte in turn
        garbled = bytearray(explained)
        garbled[i] ^= 0xFF
        got = decode_explain(bytes(garbled))
        assert got is None or isinstance(got, dict)
    assert decode_explain(bytes(explained)) == {"p": "x"}


def test_decode_frame_and_explain_matches_separate_decodes():
    from repro.wire import decode_explain, decode_frame_and_explain

    body = {"estimates": {"a": 1.0, "b": 2.0}}
    prov = {"a": {"route": "minmax"}, "b": {"route": "dict"}}
    for frame in (encode_frame(body), encode_frame(body, explain=prov)):
        assert decode_frame_and_explain(frame) == (
            decode_frame(frame), decode_explain(frame)
        )
    with pytest.raises(WireError):
        decode_frame_and_explain(b"\x00junk")
